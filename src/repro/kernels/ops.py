"""Public jit'd wrappers over the Pallas kernels.

These are the APIs the examples/benchmarks call: they take the host-side
substrate objects (:class:`repro.sparse.EllpackMatrix`,
:class:`repro.sparse.SellSlabs`, :class:`repro.graphs.EllpackGraph`), move
them to device, pad to the chosen VL, dispatch the kernel matching the
format, and trim the result.  ``interpret`` defaults to "not on TPU" so the
same call sites run interpreted on CPU and compiled on real hardware.
"""
from __future__ import annotations

import warnings

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.autotune import SellTuneResult, tune_sell_layout
from repro.graphs.gen import EllpackGraph, graph_to_sell_slabs
from repro.kernels import bfs as bfs_k
from repro.kernels import fft as fft_k
from repro.kernels import pagerank as pr_k
from repro.kernels import sell as sell_k
from repro.kernels import spmv as spmv_k
from repro.kernels.ref import fft_twiddles
from repro.sparse.formats import (
    CSRMatrix,
    EllpackMatrix,
    SellCSigmaMatrix,
    SellSlabs,
    csr_to_ellpack,
    csr_to_sell_slabs,
    sell_to_slabs,
    to_csr,
)

PAD = -1
INF = np.iinfo(np.int32).max


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# SpMV
# ---------------------------------------------------------------------------


def _repack_warn(matrix, vl: int):
    """Repack a matrix whose slice width disagrees with the requested vl."""
    warnings.warn(
        f"matrix packed with C={matrix.c}, requested vl={vl}: repacking "
        "(pack with the target vl to avoid this cost)",
        stacklevel=3,
    )
    return to_csr(matrix)


def _spmv_slabs(slabs: SellSlabs, x, *, w_block: int, interpret: bool) -> jnp.ndarray:
    return sell_k.spmv_sell(
        tuple(jnp.asarray(c) for c in slabs.bucket_cols),
        tuple(jnp.asarray(v) for v in slabs.bucket_vals),
        tuple(jnp.asarray(r) for r in slabs.bucket_rows),
        jnp.asarray(x),
        n_rows=slabs.n_rows,
        w_block=w_block,
        interpret=interpret,
    )


def spmv(
    matrix: CSRMatrix | EllpackMatrix | SellCSigmaMatrix | SellSlabs,
    x: np.ndarray | jnp.ndarray,
    *,
    vl: int = 256,
    sigma: int | None = None,
    w_block: int = 8,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """y = A @ x, dispatching the kernel that matches the matrix format.

    * :class:`CSRMatrix` — packed to width-bucketed SELL slabs at slice
      width ``vl`` (sigma defaults to 8*vl) and run bucket-by-bucket;
    * :class:`SellSlabs` / :class:`SellCSigmaMatrix` — bucketed kernel;
    * :class:`EllpackMatrix` — the uniform-width kernel.

    A pre-packed matrix whose C disagrees with ``vl`` is repacked with a
    warning instead of failing.
    """
    interpret = default_interpret() if interpret is None else interpret
    if not isinstance(matrix, CSRMatrix) and matrix.c != vl:
        matrix = _repack_warn(matrix, vl)
    if isinstance(matrix, CSRMatrix):
        matrix = csr_to_sell_slabs(matrix, c=vl, sigma=sigma)
    if isinstance(matrix, SellCSigmaMatrix):
        matrix = sell_to_slabs(matrix)
    if isinstance(matrix, SellSlabs):
        return _spmv_slabs(matrix, x, w_block=w_block, interpret=interpret)
    y = spmv_k.spmv_ell(
        jnp.asarray(matrix.cols),
        jnp.asarray(matrix.vals),
        jnp.asarray(x),
        w_block=min(w_block, matrix.width),
        interpret=interpret,
    )
    return y[: matrix.n_rows]


def pack_tuned(
    matrix: CSRMatrix, machine=None
) -> tuple[SellSlabs, SellTuneResult]:
    """Autotune (C, sigma, w_block) for this matrix and pack it.

    The co-design loop as an API: measure the pad_factor every candidate
    layout would produce on the actual row-length distribution, score
    SDV-modeled cycles, and return the packed winner plus the tune table.
    Feed the result straight to :func:`spmv`:

        slabs, tuned = pack_tuned(csr)
        y = spmv(slabs, x, vl=tuned.c, w_block=tuned.w_block)
    """
    tuned = tune_sell_layout(
        matrix.row_lengths, n_cols=matrix.n_cols, machine=machine
    )
    return csr_to_sell_slabs(matrix, c=tuned.c, sigma=tuned.sigma), tuned


# ---------------------------------------------------------------------------
# FFT
# ---------------------------------------------------------------------------


def fft(
    signal_re: np.ndarray | jnp.ndarray,
    signal_im: np.ndarray | jnp.ndarray | None = None,
    *,
    b_block: int = 8,
    interpret: bool | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Batched FFT of (batch, n) split-plane signals (n power of two)."""
    re = jnp.atleast_2d(jnp.asarray(signal_re))
    im = (
        jnp.zeros_like(re)
        if signal_im is None
        else jnp.atleast_2d(jnp.asarray(signal_im))
    )
    n = re.shape[-1]
    if n & (n - 1):
        raise ValueError(f"n must be a power of two, got {n}")
    interpret = default_interpret() if interpret is None else interpret
    wre, wim = fft_twiddles(n, re.dtype)
    b_block = min(b_block, re.shape[0])
    return fft_k.fft_stockham(re, im, wre, wim, b_block=b_block, interpret=interpret)


# ---------------------------------------------------------------------------
# BFS
# ---------------------------------------------------------------------------


def _pad_graph(adj: np.ndarray, vl: int) -> np.ndarray:
    n = adj.shape[0]
    if n % vl:
        adj = np.pad(adj, ((0, vl - n % vl), (0, 0)), constant_values=PAD)
    return adj


def bfs(
    graph: EllpackGraph,
    source: int = 0,
    *,
    vl: int = 256,
    sigma: int | None = None,
    layout: str = "ell",
    interpret: bool | None = None,
) -> np.ndarray:
    """BFS distances from ``source`` (INF = unreachable).

    ``layout="sell"`` runs the width-bucketed kernel over in-degree-sorted
    adjacency slabs: skewed-degree graphs stop paying the global max
    in-degree per node.
    """
    if layout not in ("ell", "sell"):
        raise ValueError(f"unknown layout {layout!r}: expected 'ell' or 'sell'")
    interpret = default_interpret() if interpret is None else interpret
    n = graph.n_nodes
    # Bottom-up expansion needs *in*-neighbors: a node joins the frontier if
    # one of the nodes that point AT it was reached last level.
    rgraph = graph.transpose()
    if layout == "sell":
        slabs = graph_to_sell_slabs(rgraph, c=vl, sigma=sigma)
        dist = bfs_k.bfs_sell(
            tuple(jnp.asarray(a) for a in slabs.bucket_adj),
            tuple(jnp.asarray(m) for m in slabs.bucket_nodes),
            n, source, interpret=interpret,
        )
        return np.asarray(dist)
    radj = _pad_graph(rgraph.adj, vl)
    dist = bfs_k.bfs(jnp.asarray(radj), source, vl=vl, interpret=interpret)
    return np.asarray(dist[:n])


# ---------------------------------------------------------------------------
# PageRank
# ---------------------------------------------------------------------------


def pagerank(
    graph: EllpackGraph,
    *,
    damping: float = 0.85,
    iters: int = 20,
    vl: int = 256,
    sigma: int | None = None,
    layout: str = "ell",
    interpret: bool | None = None,
) -> np.ndarray:
    """PageRank scores via the pull-style kernel on the reverse graph.

    ``layout="sell"`` uses in-degree-sorted, width-bucketed reverse
    adjacency (see :func:`bfs`).
    """
    if layout not in ("ell", "sell"):
        raise ValueError(f"unknown layout {layout!r}: expected 'ell' or 'sell'")
    interpret = default_interpret() if interpret is None else interpret
    n = graph.n_nodes
    if layout == "sell":
        slabs = graph_to_sell_slabs(graph.transpose(), c=vl, sigma=sigma)
        rank = pr_k.pagerank_sell(
            tuple(jnp.asarray(a) for a in slabs.bucket_adj),
            tuple(jnp.asarray(m) for m in slabs.bucket_nodes),
            jnp.asarray(graph.out_degree.astype(np.float64)),
            n, damping=damping, iters=iters, interpret=interpret,
        )
        return np.asarray(rank)
    radj = _pad_graph(graph.transpose().adj, vl)
    deg = jnp.asarray(
        np.pad(graph.out_degree, (0, radj.shape[0] - n)).astype(np.float64)
    )
    rank = pr_k.pagerank(
        jnp.asarray(radj), deg, damping=damping, iters=iters, vl=vl,
        n_real=n, interpret=interpret,
    )
    return np.asarray(rank[:n])
