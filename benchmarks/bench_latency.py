"""Paper Fig 3: execution time vs added memory latency, per kernel/series.

CSV columns: kernel, series, extra_latency_cycles, cycles, us_at_50MHz.
"""
from repro.core.sweep import latency_sweep


def rows():
    res = latency_sweep()
    for kernel, series, knob, cycles in res.rows():
        yield {
            "table": "fig3_latency",
            "kernel": kernel,
            "series": series,
            "knob": knob,
            "cycles": cycles,
            "us_at_50MHz": cycles / 50.0,
        }


def main():
    for r in rows():
        print(f"{r['table']},{r['kernel']},{r['series']},{r['knob']},"
              f"{r['cycles']:.0f},{r['us_at_50MHz']:.1f}")


if __name__ == "__main__":
    main()
