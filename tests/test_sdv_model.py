"""Tests of the SDV machine model against the paper's claims (§4).

These are the reproduction's validation gates: the two headline claims
(latency tolerance grows with VL; bandwidth exploitation grows with VL) must
hold over the full sweep grid, and the model must hit the paper's quoted
SpMV slowdown cells within tolerance.
"""
import math

import pytest
from _hypothesis_fallback import given, settings, st

from repro.core import sweep, traffic
from repro.core.autotune import tune_vl
from repro.core.sdv import MachineParams, SDVMachine
from repro.core.vconfig import PAPER_VLS, SCALAR_VL, VectorConfig

KERNELS = sweep.KERNELS


@pytest.fixture(scope="module")
def latency_tables():
    return sweep.slowdown_tables(sweep.latency_sweep())


@pytest.fixture(scope="module")
def bandwidth_result():
    return sweep.bandwidth_sweep()


# ---------------------------------------------------------------------------
# Paper claims
# ---------------------------------------------------------------------------


def test_claim_latency_tolerance(latency_tables):
    """Fig 4: slowdown non-increasing in VL for every added-latency row."""
    violations = sweep.check_latency_claim(latency_tables)
    assert not violations, violations


def test_claim_bandwidth_exploitation(bandwidth_result):
    """Fig 5: plateau bandwidth non-decreasing in VL; scalar plateaus early."""
    violations = sweep.check_bandwidth_claim(bandwidth_result)
    assert not violations, violations


def test_spmv_anchor_cells(latency_tables):
    """The paper quotes SpMV slowdowns: scalar 1.22x/8.78x and vl256
    1.05x/3.39x at +32/+1024 cycles.  Model must be within 10%."""
    errors = sweep.spmv_anchor_errors(latency_tables)
    for cell, err in errors.items():
        assert err < 0.10, f"anchor {cell} off by {err:.1%}"


def test_vector_beats_scalar_absolute():
    """Long vectors must be faster in absolute cycles too, for every kernel."""
    for kernel in KERNELS:
        build = traffic.TRACE_BUILDERS[kernel]
        machine = SDVMachine(MachineParams())
        scalar = machine.run(build(VectorConfig(vl=SCALAR_VL))).cycles
        vec = machine.run(build(VectorConfig(vl=256))).cycles
        assert vec < scalar / 4, f"{kernel}: vl256 {vec} vs scalar {scalar}"


def test_slowdown_tables_normalized(latency_tables):
    for kernel in KERNELS:
        for vl, curve in latency_tables[kernel].items():
            assert curve[0] == pytest.approx(1.0)
            assert all(v >= 0.999 for v in curve.values())


# ---------------------------------------------------------------------------
# Model properties (hypothesis)
# ---------------------------------------------------------------------------


@given(
    extra=st.integers(min_value=0, max_value=4096),
    delta=st.integers(min_value=1, max_value=512),
    vl=st.sampled_from((SCALAR_VL,) + PAPER_VLS),
    kernel=st.sampled_from(KERNELS),
)
@settings(max_examples=60, deadline=None)
def test_monotone_in_latency(extra, delta, vl, kernel):
    """More memory latency never makes a run faster."""
    build = traffic.TRACE_BUILDERS[kernel]
    trace = build(VectorConfig(vl=vl))
    base = MachineParams()
    t0 = SDVMachine(base.with_latency(extra)).run(trace).cycles
    t1 = SDVMachine(base.with_latency(extra + delta)).run(trace).cycles
    assert t1 >= t0 * 0.999


@given(
    bw=st.sampled_from([1, 2, 4, 8, 16, 32]),
    vl=st.sampled_from((SCALAR_VL,) + PAPER_VLS),
    kernel=st.sampled_from(KERNELS),
)
@settings(max_examples=40, deadline=None)
def test_monotone_in_bandwidth(bw, vl, kernel):
    """More bandwidth never makes a run slower."""
    build = traffic.TRACE_BUILDERS[kernel]
    trace = build(VectorConfig(vl=vl))
    base = MachineParams()
    t_lo = SDVMachine(base.with_bandwidth(bw)).run(trace).cycles
    t_hi = SDVMachine(base.with_bandwidth(2 * bw)).run(trace).cycles
    assert t_hi <= t_lo * 1.001


@given(vl=st.sampled_from(PAPER_VLS), kernel=st.sampled_from(KERNELS))
@settings(max_examples=30, deadline=None)
def test_fewer_instructions_with_longer_vectors(vl, kernel):
    """The mechanism: instruction count scales ~1/VL (the 'short reason')."""
    build = traffic.TRACE_BUILDERS[kernel]
    machine = SDVMachine(MachineParams())
    n_long = machine.run(build(VectorConfig(vl=vl))).mem_instructions
    n_scalar = machine.run(build(VectorConfig(vl=SCALAR_VL))).mem_instructions
    assert n_long < n_scalar
    # within 4x of the ideal 1/VL scaling (padding + phase structure differ)
    assert n_long < 4 * n_scalar / vl


def test_bandwidth_limiter_fraction_interface():
    """§2.3: num/den window registers (1/3 -> 33% of peak)."""
    m = MachineParams().with_bandwidth_fraction(1, 3)
    assert m.eff_bw == pytest.approx(64.0 / 3.0)
    m2 = MachineParams().with_bandwidth_fraction(1, 1)
    assert m2.eff_bw == pytest.approx(64.0)


def test_latency_controller_is_dynamic():
    """§2.2: latency reprogrammable without touching anything else."""
    m = MachineParams()
    assert m.with_latency(100).mem_latency == 150
    assert m.with_latency(100).with_latency(0).mem_latency == 50
    assert m.with_latency(100).eff_bw == m.eff_bw


# ---------------------------------------------------------------------------
# Co-design autotuner
# ---------------------------------------------------------------------------


def test_autotune_prefers_long_vectors_on_fpga_sdv():
    """On the paper's machine, modeled-best VL should be the longest one for
    the memory-bound kernels — the paper's central recommendation."""
    for kernel in ("spmv", "pagerank"):
        res = tune_vl(
            traffic.TRACE_BUILDERS[kernel],
            machine=MachineParams(extra_latency=256),
            candidates=list(PAPER_VLS),
        )
        assert res.vl >= 128, f"{kernel} tuned to vl={res.vl}"
        assert res.speedup_over_worst() > 1.5


def test_autotune_respects_vmem_budget():
    res = tune_vl(
        traffic.TRACE_BUILDERS["spmv"],
        machine=MachineParams(),
        candidates=[8, 16, 32, 64],
        bytes_per_vl_row=1024.0,
        vmem_budget=32 * 1024.0,
    )
    assert res.vl <= 32


def test_trace_meta_and_breakdown():
    trace = traffic.TRACE_BUILDERS["spmv"](VectorConfig(vl=64))
    run = SDVMachine(MachineParams()).run(trace)
    bd = run.breakdown()
    assert set(bd) == {"transfer", "compute", "exposure"}
    assert run.cycles > 0 and run.dram_bytes > 0
    assert math.isfinite(run.cycles)
