"""Fixture: hardcoded VMEM budget literal (vmem-budget-literal)."""


def fits_in_vmem(footprint_bytes: int) -> bool:
    return footprint_bytes <= 64 * 1024 * 1024  # the one violation
