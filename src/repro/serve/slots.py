"""Slot-based admission/coalescing loop — the one batching core.

Both serving engines in this repo multiplex a request queue onto a fixed
number of slots: the LM batcher (:class:`repro.serve.batcher.Batcher`) fills
decode slots with prompts, the sparse-kernel service
(:class:`repro.service.service.KernelService`) fills them with kernel calls
against registered operands.  The admission loop — evict finished requests,
admit queued ones into free slots, execute one step over whatever is active —
is identical, so it lives here once and the two engines subclass it with
their domain-specific ``admit`` / ``execute`` / ``done`` hooks.

The loop is deliberately synchronous and single-threaded: ``submit`` only
enqueues (the async edge of the API), and ``step``/``run``/``drain`` advance
the world.  That keeps the engines deterministic and testable while matching
the production shape (one scheduler thread feeding a device executor).
"""
from __future__ import annotations

from collections import deque
from typing import Generic, Sequence, TypeVar

R = TypeVar("R")


class SlotLoop(Generic[R]):
    """Fixed-width slot multiplexer: queue -> slots -> step -> evict.

    Subclasses implement:

    * ``done(request)``           — is this request finished?
    * ``execute(active)``         — one step over the ``(slot, request)``
      pairs currently occupying slots (the coalescing point: a subclass may
      group them however its kernels batch best).
    * ``admit(slot, request)``    — optional per-admission work (e.g. the LM
      batcher's prefill-and-splice); default no-op.
    * ``retire(request)``         — optional hook when a finished request
      leaves its slot; default no-op.
    """

    def __init__(self, n_slots: int):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        self.n_slots = n_slots
        self.queue: deque[R] = deque()
        self.slots: list[R | None] = [None] * n_slots
        self.completed: list[R] = []

    # -- hooks -------------------------------------------------------------
    def done(self, request: R) -> bool:
        raise NotImplementedError

    def execute(self, active: Sequence[tuple[int, R]]) -> None:
        raise NotImplementedError

    def admit(self, slot: int, request: R) -> None:
        pass

    def retire(self, request: R) -> None:
        pass

    def observe_step(self, queued: int, in_flight: int) -> None:
        """Optional per-round observation point, called once per ``step``
        after admission with the post-admission queue depth and the number
        of occupied slots.  Default no-op; the kernel service publishes
        these as gauges (:mod:`repro.obs`)."""

    # -- the loop ----------------------------------------------------------
    def submit(self, request: R) -> None:
        self.queue.append(request)

    @property
    def pending(self) -> int:
        """Requests not yet completed (queued + in slots)."""
        return len(self.queue) + sum(r is not None for r in self.slots)

    def active(self) -> list[tuple[int, R]]:
        return [(i, r) for i, r in enumerate(self.slots) if r is not None]

    def _evict_done(self) -> None:
        for i, req in enumerate(self.slots):
            if req is not None and self.done(req):
                self.retire(req)
                self.completed.append(req)
                self.slots[i] = None

    def _fill_slots(self) -> None:
        for i in range(self.n_slots):
            if self.slots[i] is None and self.queue:
                req = self.queue.popleft()
                self.slots[i] = req
                self.admit(i, req)

    def step(self) -> bool:
        """One scheduling round: evict, admit, execute.  False = idle."""
        self._evict_done()
        self._fill_slots()
        act = self.active()
        self.observe_step(len(self.queue), len(act))
        if not act:
            return False
        self.execute(act)
        return True

    def run(self, max_steps: int = 10_000) -> list[R]:
        """Drive the loop until the queue and all slots drain."""
        steps = 0
        while (self.queue or any(r is not None for r in self.slots)) \
                and steps < max_steps:
            if not self.step():
                break
            steps += 1
        self._evict_done()
        return self.completed
